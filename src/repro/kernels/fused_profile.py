"""Fused encode->search Pallas megakernel (the whole query hot path).

Acc-Demeter's headline efficiency comes from *never materializing* the
encoded read hypervectors off-chip: the encoder unit streams each read's
n-gram tokens and the finished HD vector flows straight into the AM
crossbar (paper §5).  The software pipeline so far ran the two kernels
separately — ``hdc_encoder`` writes the full ``(B, W)`` encoded matrix to
HBM, ``hamming_am``/``am_matmul`` reads it back.  This kernel is the TPU
realization of the paper's dataflow: one grid cell encodes a
``(bb, bw)`` word tile of the query batch *in VMEM* and immediately folds
it into the Hamming accumulator against every prototype's matching word
tile, so the encoded queries live only as a VMEM temporary.

With the encoded queries VMEM-resident, the *prototype stream* is the
only remaining HBM traffic of the search, and its dataflow is what this
kernel optimizes (the software analogue of Acc-Demeter keeping the AM
inside the memristor array):

* **In-grid prototype chunking.**  The grid is three-axis,
  ``(S/bs, B/bb, W/bw)`` with the prototype-chunk axis *outermost* — one
  ``pallas_call`` covers the whole ``(B, S)`` output instead of one call
  (and one retrace) per host-side ``bs`` chunk.
* **Chunk-slab amortization.**  Each ``(bs, W)`` prototype slab is
  delivered as a single block whose index depends only on the chunk id
  ``k``, so the pipeline fetches it ONCE per chunk and every batch tile
  ``i`` and word tile ``j`` under that chunk reuses the VMEM-resident
  copy.  Prototype HBM bytes per call drop from
  ``(B/bb) * S * W * 4`` to ``S * W * 4`` — amortized ``B/bb``-fold.
* **Double-buffered prototype DMA** (``double_buffer=True``; the default
  on real TPU).  The prototype array stays in HBM
  (``memory_space=ANY``) and the kernel copies slab ``k+1`` into the
  spare half of a two-slot VMEM scratch *at the first cell of chunk
  ``k``*, overlapping the fetch with the whole slab's worth of
  XOR+popcount work.  The automatic pipeline only prefetches one grid
  step ahead — it would start fetching slab ``k+1`` during the *last*
  cell of chunk ``k``, too late to hide a multi-megabyte copy.  Under
  interpret mode and on non-TPU backends the kernel falls back to the
  automatic pipeline (same math, same bytes; both paths are bit-exact
  and parity-tested in ``tests/test_fused.py``).

Per grid cell ``(k, i, j)``:

  1. **Encode** the ``(bb, bw)`` word tile exactly as
     ``hdc_encoder._kernel`` does: gather-free IM lookup (4 predicated
     selects), per-bit bundling counters in ``(bb, 32, bw)`` scratch,
     majority threshold with the tie-break vector, re-pack to
     ``(bb, bw)`` uint32 — all VMEM.
  2. **Search**: XOR the fresh tile against word tile ``j`` of prototype
     slab ``k`` and accumulate popcounts into the persistent
     ``(bb, bs)`` Hamming scratch.
  3. On the last word tile, flush ``agreement = dim - hamming`` into the
     ``(i, k)`` output block — the only HBM write of the whole query
     path besides the final scores.

The word axis is innermost ("arbitrary": it carries the accumulator);
the IM, tie, and prototype arrays arrive word-split as ``(..., W/bw,
bw)`` so the per-cell word tile is a *sublane-dim* dynamic index (TPU
supports those; lane-dim dynamic slices would need 128-alignment).
Bit-exact with ``reference`` encode + agreement by construction — the
encode math is byte-for-byte the encoder kernel's, and
``dim - popcount(xor)`` is the same exact integer identity both AM
kernels use.

VMEM per cell: ``bs*W*4`` (prototype slab; x2 when double-buffered) +
``bb*bs*4`` (accumulator) + ``bb*bs*4`` (output block) + ``bb*32*bw*4``
(counters) + ``n*alphabet*W*4`` (IM); callers bound ``bs`` per chunk
(see ``ops.fused_agreement`` / ``repro.kernels.autotune``).
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

from repro.kernels.pallas_compat import (ANY, CompilerParams, VMEM,
                                         SemaphoreDMA, interpret_default,
                                         make_async_copy)

WORD_BITS = 32


def _unpack(words: jax.Array) -> jax.Array:
    """(bb, bw) uint32 -> (bb, 32, bw) int32 bits (bit b in sublane b)."""
    shifts = jnp.arange(WORD_BITS, dtype=jnp.uint32)[None, :, None]
    return ((words[:, None, :] >> shifts) & jnp.uint32(1)).astype(jnp.int32)


def _pack(bits: jax.Array) -> jax.Array:
    """(bb, 32, bw) {0,1} -> (bb, bw) uint32."""
    weights = (jnp.uint32(1) << jnp.arange(WORD_BITS, dtype=jnp.uint32))
    return (bits.astype(jnp.uint32) * weights[None, :, None]).sum(
        axis=1, dtype=jnp.uint32)


def _encode_tile(tokens_ref, len_ref, im_ref, tie_ref, counts_ref, j, *,
                 n: int, alphabet: int, g: int) -> jax.Array:
    """Encode word tile ``j`` of the batch tile: ``(bb, bw)`` uint32.

    Same math as ``hdc_encoder._kernel``; ``im_ref``/``tie_ref`` are the
    word-split ``(n, alphabet, W/bw, bw)`` / ``(1, W/bw, bw)`` views and
    ``j`` picks the tile with a sublane-dim dynamic index.
    """
    toks = tokens_ref[...]                       # (bb, L) int32
    m = jnp.maximum(len_ref[...] - (n - 1), 0)   # (bb, 1) valid grams
    counts_ref[...] = jnp.zeros_like(counts_ref)
    bb = counts_ref.shape[0]
    bw = counts_ref.shape[-1]
    im_tile = im_ref[:, :, j, :]                 # (n, alphabet, bw)

    if g > 0:
        def body(i, _):
            window = jax.lax.dynamic_slice(toks, (0, i), (bb, n))  # (bb, n)
            gram = jnp.zeros((bb, bw), jnp.uint32)
            for jj in range(n):                   # bind: XOR of rho^j(B[c])
                tok_j = window[:, jj][:, None]    # (bb, 1)
                for a in range(alphabet):         # gather-free IM lookup
                    row = im_tile[jj, a, :][None, :]
                    gram = jnp.bitwise_xor(
                        gram, jnp.where(tok_j == a, row, jnp.uint32(0)))
            valid = (i < m[:, 0])[:, None, None]  # (bb, 1, 1)
            counts_ref[...] += jnp.where(valid, _unpack(gram), 0)
            return 0

        jax.lax.fori_loop(0, g, body, 0)

    counts = counts_ref[...]                      # (bb, 32, bw)
    twice = 2 * counts
    m_b = m[:, 0][:, None, None]
    tie_bits = _unpack(tie_ref[:, j, :])[0:1]     # (1, 32, bw)
    bits = jnp.where(twice == m_b, tie_bits,
                     (twice > m_b).astype(jnp.int32))
    return _pack(bits)                            # (bb, bw) — VMEM only


def _search_tile(acc_ref, o_ref, q, p_tile, *, dim: int):
    """Fold one encoded tile into the Hamming accumulator; flush on last j."""
    x = jnp.bitwise_xor(q[:, None, :], p_tile[None, :, :])
    acc_ref[...] += jnp.bitwise_count(x).astype(jnp.int32).sum(axis=-1)

    @pl.when(pl.program_id(2) == pl.num_programs(2) - 1)
    def _flush():
        o_ref[...] = dim - acc_ref[...]


def _kernel(tokens_ref, len_ref, im_ref, tie_ref, p_ref, o_ref,
            counts_ref, acc_ref, *, n: int, alphabet: int, g: int, dim: int):
    """Automatic-pipeline variant: the ``(bs, W)`` prototype slab is a
    BlockSpec block indexed by the chunk id only, so the pipeline fetches
    it once per chunk and double-buffers the fetch across chunks."""
    j = pl.program_id(2)

    @pl.when(j == 0)
    def _init():
        acc_ref[...] = jnp.zeros_like(acc_ref)

    q = _encode_tile(tokens_ref, len_ref, im_ref, tie_ref, counts_ref, j,
                     n=n, alphabet=alphabet, g=g)
    _search_tile(acc_ref, o_ref, q, p_ref[:, j, :], dim=dim)


def _kernel_dma(tokens_ref, len_ref, im_ref, tie_ref, p_hbm, o_ref,
                counts_ref, acc_ref, p_buf, sem, *,
                n: int, alphabet: int, g: int, dim: int):
    """Manual double-buffer variant: prototypes stay in HBM and slab
    ``k+1``'s async copy is issued at the FIRST cell of chunk ``k`` —
    the whole slab's compute window hides the next fetch."""
    k, i, j = pl.program_id(0), pl.program_id(1), pl.program_id(2)
    bs = p_buf.shape[1]

    def slab_dma(slot, chunk):
        return make_async_copy(p_hbm.at[pl.ds(chunk * bs, bs)],
                               p_buf.at[slot], sem.at[slot])

    @pl.when((i == 0) & (j == 0))
    def _rotate():
        @pl.when(k == 0)
        def _warmup():
            slab_dma(0, 0).start()

        slab_dma(k % 2, k).wait()

        @pl.when(k + 1 < pl.num_programs(0))
        def _prefetch():
            slab_dma((k + 1) % 2, k + 1).start()

    @pl.when(j == 0)
    def _init():
        acc_ref[...] = jnp.zeros_like(acc_ref)

    q = _encode_tile(tokens_ref, len_ref, im_ref, tie_ref, counts_ref, j,
                     n=n, alphabet=alphabet, g=g)
    _search_tile(acc_ref, o_ref, q, p_buf[k % 2][:, j, :], dim=dim)


@functools.partial(jax.jit, static_argnames=("n", "alphabet", "dim", "bb",
                                             "bw", "bs", "interpret",
                                             "double_buffer"))
def fused_profile(tokens: jax.Array, lengths: jax.Array,
                  im_rolled: jax.Array, tie: jax.Array,
                  p_packed: jax.Array, *, n: int, dim: int,
                  alphabet: int = 4, bb: int = 8, bw: int = 128,
                  bs: int | None = None, interpret: bool | None = None,
                  double_buffer: bool | None = None) -> jax.Array:
    """Agreement of every read against every prototype, single kernel.

    Args:
      tokens: ``(B, L)`` int32 symbol ids in [0, alphabet).
      lengths: ``(B, 1)`` int32 true lengths.
      im_rolled: ``(N, alphabet, W)`` uint32 — ``item_memory.rolled``.
      tie: ``(1, W)`` uint32 tie-break vector.
      p_packed: ``(S, W)`` uint32 packed prototypes (zero-padded words
        and rows are inert: pad words XOR to zero against the pad words
        of the encoded queries, which are also zero).
      dim: the LOGICAL HD dimension D (<= 32*W).
      bs: prototype rows per chunk (the third grid axis); ``None`` means
        one chunk covering all of S.  Must divide S; pad upstream
        (``ops.fused_agreement`` pads once for the whole call).
      double_buffer: manually double-buffer the prototype-slab DMA
        (prototypes stay in HBM, two-slot VMEM scratch).  ``None`` picks
        it on real TPU and falls back to the automatic pipeline under
        interpret / non-TPU backends.  Both variants are bit-exact.

    Returns:
      ``(B, S)`` int32 agreement counts in [0, dim] — bit-identical to
      ``am_agreement(hdc_encode(...), p_packed)``.
    """
    b, length = tokens.shape
    n_im, a_im, w = im_rolled.shape
    s, w2 = p_packed.shape
    assert n_im == n and a_im == alphabet and w == w2, (n_im, a_im, w, w2)
    g = max(length - n + 1, 0)
    bb, bw = min(bb, b), min(bw, w)
    bs = s if bs is None else min(bs, s)
    assert b % bb == 0 and w % bw == 0 and s % bs == 0, (
        f"(B={b}, S={s}, W={w}) must tile by (bb={bb}, bs={bs}, bw={bw}); "
        f"pad upstream")
    interpret = interpret_default(interpret)
    if double_buffer is None:
        double_buffer = (not interpret and make_async_copy is not None
                         and jax.default_backend() == "tpu")
    grid = (s // bs, b // bb, w // bw)
    wt = w // bw

    # Word-split views: the per-cell word tile becomes a sublane-dim
    # dynamic index instead of a lane-dim slice, and the IM / tie /
    # prototype block indices stop depending on j — the IM and tie are
    # fetched once per call, the prototype slab once per chunk.
    im4 = im_rolled.reshape(n, alphabet, wt, bw)
    tie3 = tie.reshape(1, wt, bw)
    p3 = p_packed.reshape(s, wt, bw)

    common_specs = [
        pl.BlockSpec((bb, length), lambda k, i, j: (i, 0)),
        pl.BlockSpec((bb, 1), lambda k, i, j: (i, 0)),
        pl.BlockSpec((n, alphabet, wt, bw), lambda k, i, j: (0, 0, 0, 0)),
        pl.BlockSpec((1, wt, bw), lambda k, i, j: (0, 0, 0)),
    ]
    scratch = [VMEM((bb, WORD_BITS, bw), jnp.int32),
               VMEM((bb, bs), jnp.int32)]
    if double_buffer:
        kernel = _kernel_dma
        p_spec = pl.BlockSpec(memory_space=ANY)
        scratch = scratch + [VMEM((2, bs, wt, bw), jnp.uint32),
                             SemaphoreDMA((2,))]
    else:
        kernel = _kernel
        p_spec = pl.BlockSpec((bs, wt, bw), lambda k, i, j: (k, 0, 0))

    return pl.pallas_call(
        functools.partial(kernel, n=n, alphabet=alphabet, g=g, dim=dim),
        grid=grid,
        in_specs=common_specs + [p_spec],
        out_specs=pl.BlockSpec((bb, bs), lambda k, i, j: (i, k)),
        out_shape=jax.ShapeDtypeStruct((b, s), jnp.int32),
        scratch_shapes=scratch,
        compiler_params=CompilerParams(
            dimension_semantics=("arbitrary", "arbitrary", "arbitrary")),
        interpret=interpret,
    )(tokens, lengths, im4, tie3, p3)
