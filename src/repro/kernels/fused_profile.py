"""Fused encode->search Pallas megakernel (the whole query hot path).

Acc-Demeter's headline efficiency comes from *never materializing* the
encoded read hypervectors off-chip: the encoder unit streams each read's
n-gram tokens and the finished HD vector flows straight into the AM
crossbar (paper §5).  The software pipeline so far ran the two kernels
separately — ``hdc_encoder`` writes the full ``(B, W)`` encoded matrix to
HBM, ``hamming_am``/``am_matmul`` reads it back.  This kernel is the TPU
realization of the paper's dataflow: one grid cell encodes a
``(bb, bw)`` word tile of the query batch *in VMEM* and immediately folds
it into the Hamming accumulator against every prototype's matching word
tile, so the encoded queries live only as a VMEM temporary.

Per grid cell ``(i, j)``:

  1. **Encode** the word tile exactly as ``hdc_encoder._kernel`` does:
     gather-free IM lookup (4 predicated selects), per-bit bundling
     counters in ``(bb, 32, bw)`` scratch, majority threshold with the
     tie-break vector, re-pack to ``(bb, bw)`` uint32 — all VMEM.
  2. **Search**: XOR the fresh tile against the prototypes' ``(S, bw)``
     word tile and accumulate popcounts into the persistent ``(bb, S)``
     Hamming scratch.
  3. On the last word tile, flush ``agreement = dim - hamming`` — the
     only HBM write of the whole query path besides the final scores.

Grid: ``(B/bb, W/bw)`` with the word-tile axis innermost ("arbitrary":
it carries the accumulator), batch tiles parallel.  Bit-exact with
``reference`` encode + agreement by construction — the encode math is
byte-for-byte the encoder kernel's, and ``dim - popcount(xor)`` is the
same exact integer identity both AM kernels use.

VMEM per cell: ``S*bw*4`` (prototype tile) + ``bb*S*4`` (accumulator) +
``bb*32*bw*4`` (counters); callers bound S per call by chunking the
prototype axis (see ``ops.fused_agreement``).
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

from repro.kernels.pallas_compat import CompilerParams, VMEM, interpret_default

WORD_BITS = 32


def _unpack(words: jax.Array) -> jax.Array:
    """(bb, bw) uint32 -> (bb, 32, bw) int32 bits (bit b in sublane b)."""
    shifts = jnp.arange(WORD_BITS, dtype=jnp.uint32)[None, :, None]
    return ((words[:, None, :] >> shifts) & jnp.uint32(1)).astype(jnp.int32)


def _pack(bits: jax.Array) -> jax.Array:
    """(bb, 32, bw) {0,1} -> (bb, bw) uint32."""
    weights = (jnp.uint32(1) << jnp.arange(WORD_BITS, dtype=jnp.uint32))
    return (bits.astype(jnp.uint32) * weights[None, :, None]).sum(
        axis=1, dtype=jnp.uint32)


def _kernel(tokens_ref, len_ref, im_ref, tie_ref, p_ref, o_ref,
            counts_ref, acc_ref, *, n: int, alphabet: int, g: int, dim: int):
    j = pl.program_id(1)

    @pl.when(j == 0)
    def _init():
        acc_ref[...] = jnp.zeros_like(acc_ref)

    # -- encode the (bb, bw) word tile (same math as hdc_encoder._kernel) --
    toks = tokens_ref[...]                       # (bb, L) int32
    m = jnp.maximum(len_ref[...] - (n - 1), 0)   # (bb, 1) valid grams
    counts_ref[...] = jnp.zeros_like(counts_ref)
    bb = counts_ref.shape[0]
    bw = counts_ref.shape[-1]

    if g > 0:
        def body(i, _):
            window = jax.lax.dynamic_slice(toks, (0, i), (bb, n))  # (bb, n)
            gram = jnp.zeros((bb, bw), jnp.uint32)
            for jj in range(n):                   # bind: XOR of rho^j(B[c])
                tok_j = window[:, jj][:, None]    # (bb, 1)
                for a in range(alphabet):         # gather-free IM lookup
                    row = im_ref[jj, a, :][None, :]
                    gram = jnp.bitwise_xor(
                        gram, jnp.where(tok_j == a, row, jnp.uint32(0)))
            valid = (i < m[:, 0])[:, None, None]  # (bb, 1, 1)
            counts_ref[...] += jnp.where(valid, _unpack(gram), 0)
            return 0

        jax.lax.fori_loop(0, g, body, 0)

    counts = counts_ref[...]                      # (bb, 32, bw)
    twice = 2 * counts
    m_b = m[:, 0][:, None, None]
    tie_bits = _unpack(tie_ref[...])[0:1]         # (1, 32, bw)
    bits = jnp.where(twice == m_b, tie_bits,
                     (twice > m_b).astype(jnp.int32))
    q = _pack(bits)                               # (bb, bw) — VMEM only

    # -- fold the finished tile straight into the AM search ----------------
    x = jnp.bitwise_xor(q[:, None, :], p_ref[...][None, :, :])
    acc_ref[...] += jnp.bitwise_count(x).astype(jnp.int32).sum(axis=-1)

    @pl.when(j == pl.num_programs(1) - 1)
    def _flush():
        o_ref[...] = dim - acc_ref[...]


@functools.partial(jax.jit, static_argnames=("n", "alphabet", "dim", "bb",
                                             "bw", "interpret"))
def fused_profile(tokens: jax.Array, lengths: jax.Array,
                  im_rolled: jax.Array, tie: jax.Array,
                  p_packed: jax.Array, *, n: int, dim: int,
                  alphabet: int = 4, bb: int = 8, bw: int = 128,
                  interpret: bool | None = None) -> jax.Array:
    """Agreement of every read against every prototype, single kernel.

    Args:
      tokens: ``(B, L)`` int32 symbol ids in [0, alphabet).
      lengths: ``(B, 1)`` int32 true lengths.
      im_rolled: ``(N, alphabet, W)`` uint32 — ``item_memory.rolled``.
      tie: ``(1, W)`` uint32 tie-break vector.
      p_packed: ``(S, W)`` uint32 packed prototypes (zero-padded words
        and rows are inert: pad words XOR to zero against the pad words
        of the encoded queries, which are also zero).
      dim: the LOGICAL HD dimension D (<= 32*W).

    Returns:
      ``(B, S)`` int32 agreement counts in [0, dim] — bit-identical to
      ``am_agreement(hdc_encode(...), p_packed)``.
    """
    b, length = tokens.shape
    n_im, a_im, w = im_rolled.shape
    s, w2 = p_packed.shape
    assert n_im == n and a_im == alphabet and w == w2, (n_im, a_im, w, w2)
    g = max(length - n + 1, 0)
    bb, bw = min(bb, b), min(bw, w)
    assert b % bb == 0 and w % bw == 0, (
        f"(B={b}, W={w}) must tile by (bb={bb}, bw={bw}); pad upstream")
    grid = (b // bb, w // bw)

    return pl.pallas_call(
        functools.partial(_kernel, n=n, alphabet=alphabet, g=g, dim=dim),
        grid=grid,
        in_specs=[
            pl.BlockSpec((bb, length), lambda i, j: (i, 0)),
            pl.BlockSpec((bb, 1), lambda i, j: (i, 0)),
            pl.BlockSpec((n, alphabet, bw), lambda i, j: (0, 0, j)),
            pl.BlockSpec((1, bw), lambda i, j: (0, j)),
            pl.BlockSpec((s, bw), lambda i, j: (0, j)),
        ],
        out_specs=pl.BlockSpec((bb, s), lambda i, j: (i, 0)),
        out_shape=jax.ShapeDtypeStruct((b, s), jnp.int32),
        scratch_shapes=[VMEM((bb, WORD_BITS, bw), jnp.int32),
                        VMEM((bb, s), jnp.int32)],
        compiler_params=CompilerParams(
            dimension_semantics=("parallel", "arbitrary")),
        interpret=interpret_default(interpret),
    )(tokens, lengths, im_rolled, tie, p_packed)
