"""Pure-jnp oracles for every Pallas kernel (the correctness contract).

Each function computes exactly what its kernel computes, using only
``jax.numpy`` on unblocked arrays.  The kernel test suite sweeps shapes
and dtypes and asserts bit-exact equality (all outputs are integral).
"""

from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.core import bitops, encoder


def am_matmul_ref(q_pm: jax.Array, p_pm: jax.Array) -> jax.Array:
    """Agreement via +-1 matmul on unblocked fp32 arrays."""
    d = q_pm.shape[-1]
    s = q_pm.astype(jnp.float32) @ p_pm.astype(jnp.float32).T
    return ((d + s) * 0.5).astype(jnp.int32)


def hamming_am_ref(q_packed: jax.Array, p_packed: jax.Array) -> jax.Array:
    """Agreement via packed XOR+popcount on unblocked arrays."""
    dim = 32 * q_packed.shape[-1]
    ham = bitops.popcount_words(
        jnp.bitwise_xor(q_packed[:, None, :], p_packed[None, :, :]))
    return dim - ham


def hdc_encode_ref(tokens: jax.Array, lengths: jax.Array,
                   im_rolled: jax.Array, tie: jax.Array) -> jax.Array:
    """Encoder oracle: materialized grams + masked bundle + majority."""
    n, _, w = im_rolled.shape
    dim = 32 * w
    grams = encoder.encode_grams(tokens, im_rolled)      # (B, G, W)
    g = grams.shape[-2]
    m = jnp.maximum(lengths - (n - 1), 0).astype(jnp.int32)  # (B,)
    valid = (jnp.arange(g)[None, :] < m[:, None])
    bits = bitops.unpack_bits(grams)                      # (B, G, D)
    counts = (bits.astype(jnp.int32) * valid[..., None]).sum(axis=1)
    return encoder.binarize_majority(counts, m, tie)
