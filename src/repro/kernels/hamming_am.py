"""VPU associative-memory kernel: packed XNOR+popcount (paper Eq. 2).

The digital formulation the paper contrasts with its analog VMM: Hamming
distance over bit-packed uint32 words (XOR + popcount), kept here as the
*bandwidth-optimal* path — it moves 16x fewer HBM bytes than the bf16
+-1 matmul (2 B/bit -> 1/8 B/bit) at the price of living on the VPU
instead of the MXU.  The roofline analysis in EXPERIMENTS.md §Perf decides
which formulation wins per shape.

Grid: (B/bm, S/bn, W/bw), w innermost, int32 accumulation in VMEM scratch.
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

from repro.kernels.pallas_compat import CompilerParams, VMEM, interpret_default


def _kernel(q_ref, p_ref, o_ref, acc_ref, *, dim: int):
    w = pl.program_id(2)

    @pl.when(w == 0)
    def _init():
        acc_ref[...] = jnp.zeros_like(acc_ref)

    x = jnp.bitwise_xor(q_ref[...][:, None, :], p_ref[...][None, :, :])
    acc_ref[...] += jnp.bitwise_count(x).astype(jnp.int32).sum(axis=-1)

    @pl.when(w == pl.num_programs(2) - 1)
    def _flush():
        o_ref[...] = dim - acc_ref[...]


@functools.partial(jax.jit, static_argnames=("dim", "bm", "bn", "bw",
                                              "interpret"))
def hamming_am(q_packed: jax.Array, p_packed: jax.Array, *,
               dim: int | None = None, bm: int = 8, bn: int = 128,
               bw: int = 256, interpret: bool | None = None) -> jax.Array:
    """Agreement scores between packed queries and prototypes.

    Args:
      q_packed: ``(B, W)`` uint32 packed query HD vectors (zero-padded
        words XOR to zero and add no popcount).
      p_packed: ``(S, W)`` uint32 packed prototypes.
      dim: logical HD dimension (defaults to 32*W).

    Returns:
      ``(B, S)`` int32 agreement counts in [0, dim].
    """
    b, w = q_packed.shape
    s, w2 = p_packed.shape
    assert w == w2, (w, w2)
    dim = 32 * w if dim is None else dim
    bm, bn, bw = min(bm, b), min(bn, s), min(bw, w)
    assert b % bm == 0 and s % bn == 0 and w % bw == 0, (
        f"shapes ({b},{s},{w}) must tile by ({bm},{bn},{bw}); pad upstream")
    grid = (b // bm, s // bn, w // bw)

    return pl.pallas_call(
        functools.partial(_kernel, dim=dim),
        grid=grid,
        in_specs=[
            pl.BlockSpec((bm, bw), lambda i, j, k: (i, k)),
            pl.BlockSpec((bn, bw), lambda i, j, k: (j, k)),
        ],
        out_specs=pl.BlockSpec((bm, bn), lambda i, j, k: (i, j)),
        out_shape=jax.ShapeDtypeStruct((b, s), jnp.int32),
        scratch_shapes=[VMEM((bm, bn), jnp.int32)],
        compiler_params=CompilerParams(
            dimension_semantics=("parallel", "parallel", "arbitrary")),
        interpret=interpret_default(interpret),
    )(q_packed, p_packed)
