"""Public jit'd wrappers around the Pallas kernels.

These handle padding to block multiples, the packed<->+-1 conversions, and
formulation selection, so callers (core.profiler, launch drivers) can stay
shape-agnostic.  On CPU the kernels execute in interpret mode; the wrappers
are the single switch point between the MXU and VPU formulations.
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp

from repro.core import bitops, item_memory
from repro.core.hd_space import HDSpace
from repro.kernels import am_matmul as _am_matmul
from repro.kernels import hamming_am as _hamming_am
from repro.kernels import hdc_encoder as _hdc_encoder


def pad_to_multiple(x: jax.Array, axis: int, multiple: int,
                    fill=0) -> jax.Array:
    """Pad ``x`` along ``axis`` up to the next multiple of ``multiple``.

    Shared by the Pallas wrappers (block alignment), the accel crossbar
    tiling (:mod:`repro.accel.crossbar`), and the prototype-axis sharding
    (:mod:`repro.pipeline.sharded`).  The default zero fill is inert to
    downstream math; sharding passes ``fill=num_species`` for the species
    tags so the segment reduction drops padding rows.
    """
    pad = (-x.shape[axis]) % multiple
    if pad == 0:
        return x
    widths = [(0, 0)] * x.ndim
    widths[axis] = (0, pad)
    return jnp.pad(x, widths, constant_values=fill)


_pad_to = pad_to_multiple


def to_pm1(packed: jax.Array) -> jax.Array:
    """Packed bits -> {-1,+1} bf16 (the MXU encoding of the AM crossbar)."""
    bits = bitops.unpack_bits(packed)
    return (2.0 * bits.astype(jnp.bfloat16) - 1.0)


@functools.partial(jax.jit, static_argnames=("dim", "formulation"))
def am_agreement(queries: jax.Array, prototypes: jax.Array, dim: int,
                 formulation: str = "matmul") -> jax.Array:
    """Agreement (matching bits) of every query vs every prototype.

    Args:
      queries: ``(B, W)`` uint32 packed.
      prototypes: ``(S, W)`` uint32 packed.
      formulation: "matmul" (MXU, default) or "packed" (VPU popcount).

    Returns:
      ``(B, S)`` int32 agreement in [0, dim].
    """
    b, s = queries.shape[0], prototypes.shape[0]
    if formulation == "matmul":
        bk = min(512, dim)
        q = _pad_to(_pad_to(to_pm1(queries), 0, 128), 1, bk)
        p = _pad_to(_pad_to(to_pm1(prototypes), 0, 128), 1, bk)
        out = _am_matmul.am_matmul(q, p, dim=dim, bk=bk)
    elif formulation == "packed":
        bw = min(256, dim // 32)
        q = _pad_to(_pad_to(queries, 0, 8), 1, bw)
        p = _pad_to(_pad_to(prototypes, 0, 128), 1, bw)
        out = _hamming_am.hamming_am(q, p, dim=dim, bw=bw)
    else:
        raise ValueError(f"unknown formulation {formulation!r}")
    return out[:b, :s]


@functools.partial(jax.jit, static_argnames=("space",))
def hdc_encode(tokens: jax.Array, lengths: jax.Array, im: jax.Array,
               tie: jax.Array, space: HDSpace) -> jax.Array:
    """Kernel-backed Demeter read conversion (step 3).

    Same contract as :func:`repro.core.encoder.encode`.
    """
    b = tokens.shape[0]
    im_rolled = item_memory.rolled(im, space.ngram)
    toks = _pad_to(tokens.astype(jnp.int32), 0, 8)
    lens = _pad_to(lengths.astype(jnp.int32)[:, None], 0, 8)
    bw = min(128, space.num_words)
    out = _hdc_encoder.hdc_encode(
        toks, lens, im_rolled, tie[None, :], n=space.ngram,
        alphabet=space.alphabet_size, bw=bw)
    return out[:b]
