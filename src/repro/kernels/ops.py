"""Public jit'd wrappers around the Pallas kernels.

These handle padding to block multiples, the packed<->+-1 conversions, and
formulation selection, so callers (core.profiler, launch drivers) can stay
shape-agnostic.  On CPU the kernels execute in interpret mode; the wrappers
are the single switch point between the MXU and VPU formulations.
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp

from repro.core import bitops, item_memory
from repro.core.hd_space import HDSpace
from repro.kernels import am_matmul as _am_matmul
from repro.kernels import fused_profile as _fused_profile
from repro.kernels import hamming_am as _hamming_am
from repro.kernels import hdc_encoder as _hdc_encoder


def pad_to_multiple(x: jax.Array, axis: int, multiple: int,
                    fill=0) -> jax.Array:
    """Pad ``x`` along ``axis`` up to the next multiple of ``multiple``.

    Shared by the Pallas wrappers (block alignment), the accel crossbar
    tiling (:mod:`repro.accel.crossbar`), and the prototype-axis sharding
    (:mod:`repro.pipeline.sharded`).  The default zero fill is inert to
    downstream math; sharding passes ``fill=num_species`` for the species
    tags so the segment reduction drops padding rows.
    """
    pad = (-x.shape[axis]) % multiple
    if pad == 0:
        return x
    widths = [(0, 0)] * x.ndim
    widths[axis] = (0, pad)
    return jnp.pad(x, widths, constant_values=fill)


_pad_to = pad_to_multiple


def to_pm1(packed: jax.Array) -> jax.Array:
    """Packed bits -> {-1,+1} bf16 (the MXU encoding of the AM crossbar)."""
    bits = bitops.unpack_bits(packed)
    return (2.0 * bits.astype(jnp.bfloat16) - 1.0)


@functools.partial(jax.jit, static_argnames=("dim", "formulation"))
def am_agreement(queries: jax.Array, prototypes: jax.Array, dim: int,
                 formulation: str = "matmul") -> jax.Array:
    """Agreement (matching bits) of every query vs every prototype.

    Args:
      queries: ``(B, W)`` uint32 packed.
      prototypes: ``(S, W)`` uint32 packed.
      formulation: "matmul" (MXU, default) or "packed" (VPU popcount).

    Returns:
      ``(B, S)`` int32 agreement in [0, dim].
    """
    b, s = queries.shape[0], prototypes.shape[0]
    if formulation == "matmul":
        bk = min(512, dim)
        q = _pad_to(_pad_to(to_pm1(queries), 0, 128), 1, bk)
        p = _pad_to(_pad_to(to_pm1(prototypes), 0, 128), 1, bk)
        out = _am_matmul.am_matmul(q, p, dim=dim, bk=bk)
    elif formulation == "packed":
        bw = min(256, dim // 32)
        q = _pad_to(_pad_to(queries, 0, 8), 1, bw)
        p = _pad_to(_pad_to(prototypes, 0, 128), 1, bw)
        out = _hamming_am.hamming_am(q, p, dim=dim, bw=bw)
    else:
        raise ValueError(f"unknown formulation {formulation!r}")
    return out[:b, :s]


@functools.partial(jax.jit, static_argnames=("space",))
def hdc_encode(tokens: jax.Array, lengths: jax.Array, im: jax.Array,
               tie: jax.Array, space: HDSpace) -> jax.Array:
    """Kernel-backed Demeter read conversion (step 3).

    Same contract as :func:`repro.core.encoder.encode`.
    """
    b = tokens.shape[0]
    im_rolled = item_memory.rolled(im, space.ngram)
    toks = _pad_to(tokens.astype(jnp.int32), 0, 8)
    lens = _pad_to(lengths.astype(jnp.int32)[:, None], 0, 8)
    bw = min(128, space.num_words)
    out = _hdc_encoder.hdc_encode(
        toks, lens, im_rolled, tie[None, :], n=space.ngram,
        alphabet=space.alphabet_size, bw=bw)
    return out[:b]


@functools.partial(jax.jit, static_argnames=("space", "bb", "bw", "bs"))
def fused_agreement(tokens: jax.Array, lengths: jax.Array, im: jax.Array,
                    tie: jax.Array, prototypes: jax.Array, space: HDSpace,
                    *, bb: int = 8, bw: int = 128, bs: int = 4096
                    ) -> jax.Array:
    """Fused steps 3+4: read tokens -> agreement, no encoded HBM matrix.

    One :func:`repro.kernels.fused_profile.fused_profile` call per
    prototype chunk: the encoded query tile lives only in VMEM, so the
    ``(B, W)`` packed matrix (and the ±1 bf16 expansion of the matmul
    path) never touches HBM.  Bit-identical to
    ``am_agreement(hdc_encode(tokens, lengths, im, tie, space), p, dim)``.

    Args:
      tokens: ``(B, L)`` int32 symbol ids; lengths: ``(B,)`` true lengths.
      prototypes: ``(S, W)`` uint32 packed prototypes.
      bb / bw: batch / word-tile sizes (VMEM shape knobs).
      bs: prototype rows per kernel call — bounds the ``(S, bw)``
        prototype tile and ``(bb, S)`` accumulator resident in VMEM.

    Returns:
      ``(B, S)`` int32 agreement in [0, space.dim].
    """
    b, s = tokens.shape[0], prototypes.shape[0]
    im_rolled = item_memory.rolled(im, space.ngram)
    bb = min(bb, 8 * ((b + 7) // 8))
    toks = _pad_to(tokens.astype(jnp.int32), 0, max(bb, 8))
    lens = _pad_to(lengths.astype(jnp.int32)[:, None], 0, max(bb, 8))
    bw = min(bw, space.num_words)
    # Pad the word axis to the tile: zero IM/tie/prototype words encode
    # (and score) as zeros, so padding is inert to the exact agreement.
    im_rolled = _pad_to(im_rolled, 2, bw)
    tie_row = _pad_to(tie[None, :], 1, bw)
    protos = _pad_to(jnp.asarray(prototypes), 1, bw)
    cols = []
    for c0 in range(0, s, bs):
        chunk = _pad_to(protos[c0:min(c0 + bs, s)], 0, 128)
        cols.append(_fused_profile.fused_profile(
            toks, lens, im_rolled, tie_row, chunk, n=space.ngram,
            dim=space.dim, alphabet=space.alphabet_size, bb=bb,
            bw=bw)[:, :min(bs, s - c0)])
    return jnp.concatenate(cols, axis=1)[:b] if len(cols) > 1 else cols[0][:b]
