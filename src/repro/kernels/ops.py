"""Public jit'd wrappers around the Pallas kernels.

These handle padding to block multiples, the packed<->+-1 conversions, and
formulation selection, so callers (core.profiler, launch drivers) can stay
shape-agnostic.  On CPU the kernels execute in interpret mode; the wrappers
are the single switch point between the MXU and VPU formulations.
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp

from repro.core import bitops, item_memory
from repro.core.hd_space import HDSpace
from repro.kernels import am_matmul as _am_matmul
from repro.kernels import fused_profile as _fused_profile
from repro.kernels import hamming_am as _hamming_am
from repro.kernels import hdc_encoder as _hdc_encoder


# Re-exported from its dependency-free home so standalone kernel tools
# (`python -m repro.kernels.autotune`) can load without pulling in the
# whole core->pipeline import graph.
pad_to_multiple = bitops.pad_to_multiple

_pad_to = pad_to_multiple


def to_pm1(packed: jax.Array) -> jax.Array:
    """Packed bits -> {-1,+1} bf16 (the MXU encoding of the AM crossbar)."""
    bits = bitops.unpack_bits(packed)
    return (2.0 * bits.astype(jnp.bfloat16) - 1.0)


@functools.partial(jax.jit, static_argnames=("dim", "formulation"))
def am_agreement(queries: jax.Array, prototypes: jax.Array, dim: int,
                 formulation: str = "matmul") -> jax.Array:
    """Agreement (matching bits) of every query vs every prototype.

    Args:
      queries: ``(B, W)`` uint32 packed.
      prototypes: ``(S, W)`` uint32 packed.
      formulation: "matmul" (MXU, default) or "packed" (VPU popcount).

    Returns:
      ``(B, S)`` int32 agreement in [0, dim].
    """
    b, s = queries.shape[0], prototypes.shape[0]
    if formulation == "matmul":
        bk = min(512, dim)
        q = _pad_to(_pad_to(to_pm1(queries), 0, 128), 1, bk)
        p = _pad_to(_pad_to(to_pm1(prototypes), 0, 128), 1, bk)
        out = _am_matmul.am_matmul(q, p, dim=dim, bk=bk)
    elif formulation == "packed":
        bw = min(256, dim // 32)
        q = _pad_to(_pad_to(queries, 0, 8), 1, bw)
        p = _pad_to(_pad_to(prototypes, 0, 128), 1, bw)
        out = _hamming_am.hamming_am(q, p, dim=dim, bw=bw)
    else:
        raise ValueError(f"unknown formulation {formulation!r}")
    return out[:b, :s]


@functools.partial(jax.jit, static_argnames=("space",))
def hdc_encode(tokens: jax.Array, lengths: jax.Array, im: jax.Array,
               tie: jax.Array, space: HDSpace) -> jax.Array:
    """Kernel-backed Demeter read conversion (step 3).

    Same contract as :func:`repro.core.encoder.encode`.
    """
    b = tokens.shape[0]
    im_rolled = item_memory.rolled(im, space.ngram)
    toks = _pad_to(tokens.astype(jnp.int32), 0, 8)
    lens = _pad_to(lengths.astype(jnp.int32)[:, None], 0, 8)
    bw = min(128, space.num_words)
    out = _hdc_encoder.hdc_encode(
        toks, lens, im_rolled, tie[None, :], n=space.ngram,
        alphabet=space.alphabet_size, bw=bw)
    return out[:b]


def _ceil_to(x: int, m: int) -> int:
    return -(-x // m) * m


def fused_tile_plan(b: int, s: int, w: int, *, bb: int = 8, bw: int = 128,
                    bs: int = 4096) -> dict[str, int]:
    """The padded shapes + grid :func:`fused_agreement` will actually run.

    One place owns the clamp/pad arithmetic so the kernel launch and the
    analytic traffic accounting (``benchmarks/smoke.py`` /
    ``benchmarks/memory.py`` / ``repro.kernels.autotune``) can never
    drift apart.  The prototype chunking pads S ONCE to
    ``n_chunks * bs`` (``bs`` re-balanced so the pad is < one chunk) —
    not per chunk, so the accumulator waste and the timing no longer
    vary with ``S % bs``.

    Returns a dict with the effective ``bb``/``bw``/``bs``, the padded
    ``b_pad``/``w_pad``/``s_pad``, ``n_chunks``, and
    ``proto_bytes_per_call`` — the prototype-stream HBM bytes one fused
    call moves (each ``(bs, W)`` slab is fetched once per chunk and
    reused across every batch tile; see ``kernels/fused_profile``).
    """
    bb = min(bb, 8 * ((b + 7) // 8))
    b_pad = _ceil_to(b, max(bb, 8))
    bw = min(bw, w)
    w_pad = _ceil_to(w, bw)
    # Re-balance the requested chunk rows over ceil(S/bs) chunks, rounded
    # to the 128-row output lane tile, then pad S to the chunk grid: the
    # total pad is < one chunk (vs up to 127 rows per chunk before).
    bs = max(128, min(bs, _ceil_to(s, 128)))
    n_chunks = -(-s // bs)
    bs = _ceil_to(-(-s // n_chunks), 128)
    n_chunks = -(-s // bs)
    s_pad = n_chunks * bs
    return {"bb": bb, "bw": bw, "bs": bs, "b_pad": b_pad, "w_pad": w_pad,
            "s_pad": s_pad, "n_chunks": n_chunks,
            "proto_bytes_per_call": s_pad * w_pad * 4}


@functools.partial(jax.jit, static_argnames=("space", "bb", "bw", "bs",
                                             "double_buffer"))
def fused_agreement(tokens: jax.Array, lengths: jax.Array, im: jax.Array,
                    tie: jax.Array, prototypes: jax.Array, space: HDSpace,
                    *, bb: int = 8, bw: int = 128, bs: int = 4096,
                    double_buffer: bool | None = None) -> jax.Array:
    """Fused steps 3+4: read tokens -> agreement, no encoded HBM matrix.

    ONE :func:`repro.kernels.fused_profile.fused_profile` call covers the
    whole ``(B, S)`` output: the ``bs`` prototype chunking is the
    kernel's outermost grid axis (no per-chunk retrace, no host concat),
    each ``(bs, W)`` prototype slab is fetched once per chunk and reused
    across every batch tile, and on TPU the next slab's DMA is manually
    double-buffered behind the current slab's compute.  The encoded
    query tile lives only in VMEM, so the ``(B, W)`` packed matrix (and
    the ±1 bf16 expansion of the matmul path) never touches HBM.
    Bit-identical to
    ``am_agreement(hdc_encode(tokens, lengths, im, tie, space), p, dim)``.

    Args:
      tokens: ``(B, L)`` int32 symbol ids; lengths: ``(B,)`` true lengths.
      prototypes: ``(S, W)`` uint32 packed prototypes.
      bb / bw: batch / word-tile sizes (VMEM shape knobs).
      bs: prototype rows per chunk — bounds the ``(bs, W)`` slab and the
        ``(bb, bs)`` accumulator resident in VMEM.  Re-balanced and
        padded once via :func:`fused_tile_plan`.
      double_buffer: forwarded to the kernel (``None`` = auto: manual
        DMA double-buffering on real TPU, automatic pipeline elsewhere).

    Returns:
      ``(B, S)`` int32 agreement in [0, space.dim].
    """
    b, s = tokens.shape[0], prototypes.shape[0]
    plan = fused_tile_plan(b, s, space.num_words, bb=bb, bw=bw, bs=bs)
    im_rolled = item_memory.rolled(im, space.ngram)
    toks = _pad_to(tokens.astype(jnp.int32), 0, max(plan["bb"], 8))
    lens = _pad_to(lengths.astype(jnp.int32)[:, None], 0, max(plan["bb"], 8))
    # Pad the word axis to the tile and the prototype axis to the chunk
    # grid: zero IM/tie/prototype words encode (and score) as zeros, so
    # padding is inert to the exact agreement; pad rows are sliced off.
    im_rolled = _pad_to(im_rolled, 2, plan["bw"])
    tie_row = _pad_to(tie[None, :], 1, plan["bw"])
    protos = _pad_to(_pad_to(jnp.asarray(prototypes), 1, plan["bw"]),
                     0, plan["bs"])
    out = _fused_profile.fused_profile(
        toks, lens, im_rolled, tie_row, protos, n=space.ngram,
        dim=space.dim, alphabet=space.alphabet_size, bb=plan["bb"],
        bw=plan["bw"], bs=plan["bs"], double_buffer=double_buffer)
    return out[:b, :s]
