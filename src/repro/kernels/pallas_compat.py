"""Small compatibility shims for Pallas TPU across JAX versions.

The repo targets TPU (pl.pallas_call + BlockSpec VMEM tiling) but runs its
correctness suite on CPU via interpret mode; these helpers keep the
kernels identical in both worlds.
"""

from __future__ import annotations

import jax

try:  # jax >= 0.4.31 style
    from jax.experimental.pallas import tpu as pltpu
    VMEM = pltpu.VMEM
    #: The "compiler places it" memory space — inputs a kernel DMAs
    #: manually (e.g. the double-buffered prototype stream) instead of
    #: receiving as pipelined VMEM blocks.
    ANY = (pltpu.ANY if hasattr(pltpu, "ANY")
           else pltpu.TPUMemorySpace.ANY)  # older spelling
    make_async_copy = pltpu.make_async_copy

    def SemaphoreDMA(shape):
        """DMA-completion semaphore scratch (one slot per buffer)."""
        return pltpu.SemaphoreType.DMA(shape)

    def CompilerParams(**kw):
        if hasattr(pltpu, "CompilerParams"):
            return pltpu.CompilerParams(**kw)
        return pltpu.TPUCompilerParams(**kw)  # older spelling
except ImportError:  # pragma: no cover - pallas-tpu always importable in CI
    ANY = None
    make_async_copy = None

    def VMEM(shape, dtype):
        return jax.ShapeDtypeStruct(shape, dtype)

    def SemaphoreDMA(shape):
        return None

    def CompilerParams(**kw):
        return None


def interpret_default(interpret: bool | None) -> bool:
    """Kernels run natively on TPU, in interpret mode everywhere else."""
    if interpret is not None:
        return interpret
    return jax.default_backend() != "tpu"
