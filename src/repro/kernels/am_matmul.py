"""MXU associative-memory kernel: Hamming similarity as a +-1 matmul.

This is the TPU-native port of Acc-Demeter's AM crossbar (paper §5.4-5.5).
The PCM array computes ``Q.P + Qbar.Pbar = D - Ham(Q,P)`` as two analog
VMMs by Kirchhoff's law; on TPU the same identity becomes a single MXU
matmul over the +-1 encoding:

    S = Q_hat @ P_hat.T,  Q_hat = 2Q - 1 in {-1,+1}
    agreement = #matching bits = (D + S) / 2

The +-1 partial sums are integers with |S| <= D <= 2^24, exactly
representable in the fp32 accumulator — the kernel is *exact*, matching
the paper's insistence on exact XNOR+popcount (vs the 2-minterm
approximation it rejects, §5.3).

Grid: (B/bm, S/bn, D/bk), k innermost; fp32 accumulation in VMEM scratch;
block shapes default to MXU-aligned (128, 128, 512).
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

from repro.kernels.pallas_compat import CompilerParams, VMEM, interpret_default


def _kernel(q_ref, p_ref, o_ref, acc_ref, *, dim: int):
    k = pl.program_id(2)

    @pl.when(k == 0)
    def _init():
        acc_ref[...] = jnp.zeros_like(acc_ref)

    acc_ref[...] += jax.lax.dot_general(
        q_ref[...], p_ref[...],
        dimension_numbers=(((1,), (1,)), ((), ())),
        preferred_element_type=jnp.float32)

    @pl.when(k == pl.num_programs(2) - 1)
    def _flush():
        # agreement = (D + S) / 2 — exact: S and D share parity.
        o_ref[...] = ((dim + acc_ref[...]) * 0.5).astype(jnp.int32)


@functools.partial(jax.jit, static_argnames=("dim", "bm", "bn", "bk",
                                              "interpret"))
def am_matmul(q_pm: jax.Array, p_pm: jax.Array, *, dim: int | None = None,
              bm: int = 128, bn: int = 128, bk: int = 512,
              interpret: bool | None = None) -> jax.Array:
    """Agreement scores between +-1-encoded queries and prototypes.

    Args:
      q_pm: ``(B, D_pad)`` bf16 in {-1, +1}, zero-padded on the trailing
        dim to a bk multiple (zeros contribute nothing to the +-1 dot).
      p_pm: ``(S, D_pad)`` likewise.
      dim: the LOGICAL HD dimension D (defaults to D_pad).

    Returns:
      ``(B, S)`` int32 agreement counts in [0, dim].
    """
    b, d = q_pm.shape
    s, d2 = p_pm.shape
    assert d == d2, (d, d2)
    dim = d if dim is None else dim
    bm, bn, bk = min(bm, b), min(bn, s), min(bk, d)
    assert b % bm == 0 and s % bn == 0 and d % bk == 0, (
        f"shapes ({b},{s},{d}) must tile by ({bm},{bn},{bk}); pad upstream")
    grid = (b // bm, s // bn, d // bk)

    return pl.pallas_call(
        functools.partial(_kernel, dim=dim),
        grid=grid,
        in_specs=[
            pl.BlockSpec((bm, bk), lambda i, j, k: (i, k)),
            pl.BlockSpec((bn, bk), lambda i, j, k: (j, k)),
        ],
        out_specs=pl.BlockSpec((bm, bn), lambda i, j, k: (i, j)),
        out_shape=jax.ShapeDtypeStruct((b, s), jnp.int32),
        scratch_shapes=[VMEM((bm, bn), jnp.float32)],
        compiler_params=CompilerParams(
            dimension_semantics=("parallel", "parallel", "arbitrary")),
        interpret=interpret_default(interpret),
    )(q_pm, p_pm)
