"""Tile-shape autotuner for the fused megakernel.

The fused kernel's throughput is set by three tile knobs — ``bb`` (batch
rows), ``bw`` (word lanes), ``bs`` (prototype rows per chunk) — whose
best values depend on the platform (VMEM size, DMA latency) and the live
problem shape.  This module sweeps candidate configs under a VMEM-budget
feasibility filter, times :func:`repro.kernels.ops.fused_agreement` on
deterministic synthetic inputs at the live shape, and persists the
winner in an on-disk JSON cache so every later session/service/fleet
process with the same (platform, device kind, B, W, S, dim) key reuses
the tuned tiles without re-measuring.

Wired into the pipeline as ``backend_options autotune=true`` on the
``pallas_fused`` backend (see :mod:`repro.pipeline.fused`); also usable
standalone::

    PYTHONPATH=src python -m repro.kernels.autotune --smoke

Cache location: ``~/.cache/repro/autotune.json``, overridable with the
``REPRO_AUTOTUNE_CACHE`` env var or an explicit ``path=`` argument.
"""

from __future__ import annotations

import argparse
import json
import os
import sys
import tempfile
import time
from pathlib import Path

import jax
import jax.numpy as jnp
import numpy as np

from repro.core import item_memory
from repro.core.hd_space import HDSpace
from repro.kernels import ops

#: VMEM bytes the feasibility filter budgets per config.  TPU cores have
#: ~16 MiB; the margin leaves room for the compiler's own double-buffered
#: staging of the small pipelined operands.
VMEM_BUDGET = 12 * 2 ** 20

#: Default on-disk cache (see module docstring for overrides).
DEFAULT_CACHE = Path("~/.cache/repro/autotune.json")

#: Candidate axes swept by :func:`candidate_plans`.  Values infeasible or
#: redundant at a given shape are clamped/deduped by ``fused_tile_plan``.
CANDIDATE_BB = (4, 8, 16)
CANDIDATE_BW = (32, 64, 128, 256)
CANDIDATE_BS = (512, 1024, 4096, 8192)


def cache_path(path: str | os.PathLike | None = None) -> Path:
    """Resolve the cache file: explicit arg > env override > default."""
    if path is not None:
        return Path(path)
    env = os.environ.get("REPRO_AUTOTUNE_CACHE")
    return Path(env) if env else DEFAULT_CACHE.expanduser()


def cache_key(b: int, w: int, s: int, dim: int,
              device: jax.Device | None = None) -> str:
    """Cache key: (platform, device kind, B, W, S, dim)."""
    device = device or jax.devices()[0]
    kind = getattr(device, "device_kind", device.platform)
    return f"{device.platform}|{kind}|B{b}|W{w}|S{s}|D{dim}"


def load_cache(path: str | os.PathLike | None = None) -> dict:
    """Read the cache; missing or corrupt files are an empty cache."""
    try:
        return json.loads(cache_path(path).read_text())
    except (OSError, ValueError):
        return {}


def save_cache(cache: dict, path: str | os.PathLike | None = None) -> Path:
    """Atomically write the cache (temp file + rename, crash-safe)."""
    p = cache_path(path)
    p.parent.mkdir(parents=True, exist_ok=True)
    fd, tmp = tempfile.mkstemp(dir=p.parent, prefix=p.name + ".")
    try:
        with os.fdopen(fd, "w") as f:
            json.dump(cache, f, indent=2, sort_keys=True)
        os.replace(tmp, p)
    finally:
        if os.path.exists(tmp):
            os.unlink(tmp)
    return p


def vmem_bytes(plan: dict[str, int], *, read_len: int, n: int,
               alphabet: int = 4) -> int:
    """Estimate the kernel's peak VMEM residency for a tile plan.

    Mirrors the buffers ``kernels/fused_profile`` actually allocates:
    pipelined input/output blocks, the rolled-IM/tie full blocks, the
    2-slot prototype-slab double buffer (the automatic pipeline also
    keeps two in flight, so the estimate is path-independent), and the
    counts/accumulator scratch.
    """
    bb, bw, bs = plan["bb"], plan["bw"], plan["bs"]
    w_pad = plan["w_pad"]
    total = bb * read_len * 4             # token tile
    total += bb * 4                       # lengths tile
    total += n * alphabet * w_pad * 4     # rolled item memory (full block)
    total += w_pad * 4                    # tie-break row
    total += 2 * bs * w_pad * 4           # prototype slab, double-buffered
    total += bb * 32 * bw * 4             # bit-counts scratch
    total += bb * bs * 4                  # agreement accumulator scratch
    total += bb * bs * 4                  # output tile
    return total


def candidate_plans(b: int, s: int, w: int) -> list[dict[str, int]]:
    """Normalized, deduplicated tile plans for the candidate sweep."""
    seen: set[tuple[int, int, int]] = set()
    plans = []
    for bb in CANDIDATE_BB:
        for bw in CANDIDATE_BW:
            for bs in CANDIDATE_BS:
                plan = ops.fused_tile_plan(b, s, w, bb=bb, bw=bw, bs=bs)
                key = (plan["bb"], plan["bw"], plan["bs"])
                if key not in seen:
                    seen.add(key)
                    plans.append(plan)
    return plans


def _synthetic_inputs(space: HDSpace, batch: int, num_prototypes: int,
                      read_len: int, seed: int = 0):
    """Deterministic measurement inputs at the live shape."""
    rng = np.random.default_rng(seed)
    tokens = jnp.asarray(rng.integers(
        0, space.alphabet_size, (batch, read_len), dtype=np.int32))
    lengths = jnp.full((batch,), read_len, jnp.int32)
    im = item_memory.make_item_memory(space)
    tie = item_memory.make_tie_break(space)
    protos = jnp.asarray(rng.integers(
        0, 2 ** 32, (num_prototypes, space.num_words),
        dtype=np.uint32))
    return tokens, lengths, im, tie, protos


def _time_plan(plan: dict[str, int], args, space: HDSpace,
               trials: int) -> float:
    """Best-of-``trials`` wall time (s); first call compiles and warms."""
    tokens, lengths, im, tie, protos = args

    def run():
        return ops.fused_agreement(
            tokens, lengths, im, tie, protos, space,
            bb=plan["bb"], bw=plan["bw"], bs=plan["bs"])

    run().block_until_ready()
    best = float("inf")
    for _ in range(max(1, trials)):
        t0 = time.perf_counter()
        run().block_until_ready()
        best = min(best, time.perf_counter() - t0)
    return best


def tune(space: HDSpace, *, batch: int, num_prototypes: int, read_len: int,
         path: str | os.PathLike | None = None, force: bool = False,
         trials: int = 2, budget: int = VMEM_BUDGET,
         seed: int = 0) -> tuple[dict[str, int], bool]:
    """Pick (and cache) the fastest feasible tiles for the live shape.

    Returns ``(tiles, cached)`` where ``tiles`` is ``{"bb","bw","bs"}``
    and ``cached`` is True when the result came straight from the cache
    (no measurement ran — same key always yields the same tiles).
    """
    key = cache_key(batch, space.num_words, num_prototypes, space.dim)
    cache = load_cache(path)
    entry = cache.get(key)
    if entry is not None and not force:
        return {k: int(entry["tiles"][k]) for k in ("bb", "bw", "bs")}, True

    plans = candidate_plans(batch, num_prototypes, space.num_words)
    cost = dict(read_len=read_len, n=space.ngram,
                alphabet=space.alphabet_size)
    feasible = [p for p in plans if vmem_bytes(p, **cost) <= budget]
    if not feasible:  # degenerate budget: keep the leanest candidate
        feasible = [min(plans, key=lambda p: vmem_bytes(p, **cost))]

    args = _synthetic_inputs(space, batch, num_prototypes, read_len, seed)
    timed = [(_time_plan(p, args, space, trials), p) for p in feasible]
    best_t, best = min(timed, key=lambda tp: tp[0])
    tiles = {k: best[k] for k in ("bb", "bw", "bs")}
    cache[key] = {
        "tiles": tiles,
        "time_s": best_t,
        "swept": len(feasible),
        "vmem_bytes": vmem_bytes(best, **cost),
    }
    save_cache(cache, path)
    return tiles, False


def main(argv: list[str] | None = None) -> int:
    ap = argparse.ArgumentParser(
        description="Sweep fused-kernel tile shapes and cache the winner.")
    ap.add_argument("--smoke", action="store_true",
                    help="tune the CI smoke shape (dim=512, B=64, tiny "
                         "sweep) instead of a custom shape")
    ap.add_argument("--dim", type=int, default=512)
    ap.add_argument("--ngram", type=int, default=8)
    ap.add_argument("--batch", type=int, default=64)
    ap.add_argument("--prototypes", type=int, default=128)
    ap.add_argument("--read-len", type=int, default=1024)
    ap.add_argument("--trials", type=int, default=2)
    ap.add_argument("--force", action="store_true",
                    help="re-measure even on a cache hit")
    ap.add_argument("--out", default=None,
                    help="cache file (default: REPRO_AUTOTUNE_CACHE or "
                         f"{DEFAULT_CACHE})")
    args = ap.parse_args(argv)

    if args.smoke:
        # Matches benchmarks/smoke.py: SMOKE_SPACE + window/batch shape.
        space = HDSpace(dim=512, ngram=8, z_threshold=3.0)
        batch, protos, read_len = 64, 44, 1024
    else:
        space = HDSpace(dim=args.dim, ngram=args.ngram, z_threshold=3.0)
        batch, protos, read_len = args.batch, args.prototypes, args.read_len

    tiles, cached = tune(space, batch=batch, num_prototypes=protos,
                         read_len=read_len, path=args.out,
                         force=args.force, trials=args.trials)
    print(json.dumps({
        "key": cache_key(batch, space.num_words, protos, space.dim),
        "tiles": tiles,
        "cached": cached,
        "cache": str(cache_path(args.out)),
    }, indent=2))
    return 0


if __name__ == "__main__":
    sys.exit(main())
