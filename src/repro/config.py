"""Config system: model/arch configs, shapes, and run settings.

Every assigned architecture is a :class:`ModelConfig` built in
``repro.configs.<id>``; shapes (train_4k / prefill_32k / decode_32k /
long_500k) live in ``repro.configs.shapes``.  Configs are frozen
dataclasses — hashable, usable as jit static args, and serializable for
checkpoint metadata.
"""

from __future__ import annotations

import dataclasses
from typing import Literal


@dataclasses.dataclass(frozen=True)
class AttnConfig:
    kind: Literal["gqa", "mla"] = "gqa"
    num_heads: int = 8
    num_kv_heads: int = 8
    head_dim: int = 128
    rope_theta: float = 10_000.0
    rope_fraction: float = 1.0        # partial rotary (stablelm: 0.25)
    window: int | None = None         # sliding-window attention
    # MLA (deepseek-v2) fields:
    kv_lora: int = 0                  # compressed KV latent width
    q_lora: int = 0                   # 0 = direct q projection (V2-Lite)
    rope_head_dim: int = 64           # decoupled RoPE key width
    v_head_dim: int = 0               # 0 = head_dim

    @property
    def vdim(self) -> int:
        return self.v_head_dim or self.head_dim

    @property
    def q_groups(self) -> int:
        assert self.num_heads % self.num_kv_heads == 0
        return self.num_heads // self.num_kv_heads


@dataclasses.dataclass(frozen=True)
class MoEConfig:
    num_experts: int
    top_k: int
    d_expert: int
    num_shared: int = 0               # shared (always-on) experts
    capacity_factor: float = 1.25
    group_size: int = 512             # dispatch group (tokens)
    aux_loss_coef: float = 0.01
    router_scale: bool = True         # normalize top-k weights to sum 1


@dataclasses.dataclass(frozen=True)
class SSMConfig:
    d_state: int = 128
    d_conv: int = 4
    expand: int = 2
    head_dim: int = 64
    chunk: int = 128
    n_groups: int = 1


@dataclasses.dataclass(frozen=True)
class ModelConfig:
    name: str
    family: Literal["dense", "moe", "ssm", "hybrid", "audio", "vlm"]
    n_layers: int
    d_model: int
    d_ff: int
    vocab: int
    attn: AttnConfig | None = None
    moe: MoEConfig | None = None
    ssm: SSMConfig | None = None
    act: Literal["silu", "gelu", "relu2"] = "silu"
    glu: bool = True
    norm: Literal["rmsnorm", "layernorm"] = "rmsnorm"
    tie_embeddings: bool = False
    # MoE models: leading layers that stay dense, and their ffn width.
    n_dense_layers: int = 0
    dense_d_ff: int = 0
    # Hybrid (hymba): every layer runs attention and SSM heads in parallel;
    # `global_attn_layers` use full attention, others use `attn.window`.
    global_attn_every: int = 0
    # Encoder-decoder (whisper): n_layers is the decoder depth.
    n_enc_layers: int = 0
    dec_len_train: int = 512          # decoder length for train shapes
    # VLM (paligemma): number of stub patch-embedding prefix tokens.
    vlm_prefix: int = 0
    # Positional scheme.
    pos: Literal["rope", "sinusoidal"] = "rope"
    param_dtype: str = "bfloat16"

    @property
    def is_encdec(self) -> bool:
        return self.n_enc_layers > 0

    @property
    def quadratic_attention(self) -> bool:
        """True if decode-time cost/memory grows linearly with context for
        every layer (full attention) — disqualifies long_500k."""
        if self.family in ("ssm",):
            return False
        if self.family == "hybrid":
            return False  # SWA + SSM; few global layers bounded by design
        return True

    def active_params_per_layer(self) -> int:
        """Approximate active parameter count of one layer (for 6ND)."""
        d = self.d_model
        n = 0
        if self.attn is not None:
            a = self.attn
            if a.kind == "mla":
                qdim = a.num_heads * (a.head_dim + a.rope_head_dim)
                n += d * qdim                                  # W_q
                n += d * (a.kv_lora + a.rope_head_dim)         # W_dkv, W_kr
                n += a.kv_lora * a.num_heads * (a.head_dim + a.vdim)
                n += a.num_heads * a.vdim * d                  # W_o
            else:
                n += d * a.num_heads * a.head_dim              # W_q
                n += 2 * d * a.num_kv_heads * a.head_dim       # W_k, W_v
                n += a.num_heads * a.vdim * d                  # W_o
        if self.ssm is not None and self.family in ("ssm", "hybrid"):
            s = self.ssm
            d_in = s.expand * d
            conv_dim = d_in + 2 * s.n_groups * s.d_state
            nheads = d_in // s.head_dim
            n += d * (2 * d_in + 2 * s.n_groups * s.d_state + nheads)
            n += conv_dim * s.d_conv
            n += d_in * d
        if self.moe is not None:
            m = self.moe
            mult = 3 if self.glu else 2
            n += (m.top_k + m.num_shared) * mult * d * m.d_expert
            n += d * m.num_experts                              # router
        else:
            mult = 3 if self.glu else 2
            n += mult * d * self.d_ff
        return n

    def active_param_count(self) -> int:
        """Active (per-token) parameters — 6*N*D model FLOPs uses this."""
        n = self.n_layers * self.active_params_per_layer()
        if self.n_dense_layers and self.moe is not None:
            mult = 3 if self.glu else 2
            moe_ffn = (self.moe.top_k + self.moe.num_shared) * mult * \
                self.d_model * self.moe.d_expert
            dense_ffn = mult * self.d_model * (self.dense_d_ff or self.d_ff)
            n += self.n_dense_layers * (dense_ffn - moe_ffn)
        if self.is_encdec:
            # encoder layers + decoder cross-attn (roughly one extra attn)
            n += self.n_enc_layers * self.active_params_per_layer()
            if self.attn:
                a = self.attn
                n += self.n_layers * (2 * self.d_model * a.num_heads * a.head_dim
                                      + 2 * self.d_model * a.num_kv_heads * a.head_dim)
        n += self.d_model * self.vocab * (1 if self.tie_embeddings else 2)
        return n
